"""End-to-end training driver: train a ~100M-param qwen2.5-style model
for a few hundred steps through the full production stack (pipeline,
AdamW + cosine schedule, grad clipping, checkpointing, fault-tolerant
loop, straggler monitor).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

~100M params is CPU-heavy; --small trains the smoke config instead
(default here so the example completes in minutes).
"""

import argparse
import dataclasses
import logging

from repro.configs import ModelConfig, RunConfig, ShapeConfig
from repro.launch.train import train


def hundred_m_config() -> ModelConfig:
    """A ~100M-param decoder-only config (qwen-style)."""
    return ModelConfig(
        name="qwen-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32000,
        qkv_bias=True, norm="rmsnorm", activation="swiglu",
        dtype="float32", attn_chunk=256, remat=False,
    )


def tiny_config() -> ModelConfig:
    return ModelConfig(
        name="qwen-tiny", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=384, vocab_size=4096,
        qkv_bias=True, norm="rmsnorm", activation="swiglu",
        dtype="float32", attn_chunk=128, remat=False,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full-100m", action="store_true",
                    help="train the ~100M config (slow on 1 CPU core)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(message)s")

    cfg = hundred_m_config() if args.full_100m else tiny_config()
    n_params_est = cfg.param_count()
    print(f"training {cfg.name}: ~{n_params_est/1e6:.0f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    run = RunConfig(steps=args.steps, lr=1e-3, warmup_steps=20,
                    checkpoint_dir=args.ckpt_dir, checkpoint_every=100,
                    log_every=20)
    shape = ShapeConfig("train", "train", args.seq, args.batch)
    state, info = train(cfg, run, shape=shape)
    print(f"loss: {info['losses'][0]:.3f} -> {info['losses'][-1]:.3f} "
          f"over {info['end_step']} steps "
          f"(recoveries={info['recoveries']}, "
          f"median step {info['median_step_s']:.2f}s)")


if __name__ == "__main__":
    main()
