"""Traced reconstruction: one service burst under the telemetry layer.

Runs the serving layer exactly like ``serve_recon.py`` — a warmed-up
batched burst plus one streamed session — but inside
``telemetry.tracing(...)``, then shows what the observability layer
produces:

  1. ``recon_trace.json`` — Chrome trace-event JSON. Open it at
     https://ui.perfetto.dev: the service worker, flusher, and stream
     threads are separate lanes; every ``compile`` span is one
     ProgramCache jit miss; every ``step.dispatch`` span carries the
     planner's roofline model (bytes moved, FLOPs, arithmetic
     intensity) as span args.
  2. The request-ID -> batch-dispatch linkage: each ``submit()`` mints
     a trace ID (returned on the future), and the ``service.dispatch``
     span that executed a k-wide batch lists all k IDs in its args —
     one dispatch span fans back out to every request it served.
  3. The Prometheus text exposition from ``ServiceStats`` — the same
     numbers a scrape endpoint would serve.

    PYTHONPATH=src python examples/trace_recon.py
    # or: make trace
"""

import time

import numpy as np

import jax.numpy as jnp

from repro.core import shepp_logan_3d, standard_geometry
from repro.core.forward import forward_project
from repro.runtime import telemetry
from repro.runtime.service import ReconService

TRACE_PATH = "recon_trace.json"


def main() -> None:
    geom = standard_geometry(n=24, n_det=32, n_proj=16)
    phantom = jnp.asarray(shepp_logan_3d(geom.nx))
    projs = forward_project(phantom, geom, oversample=2.0)
    opts = dict(variant="algorithm1_mp", nb=4, proj_batch=8)

    with telemetry.tracing(TRACE_PATH):
        with ReconService(max_inflight=2, max_batch=4,
                          max_wait_ms=10.0) as svc:
            svc.warmup([geom], **opts)

            # batched burst: same-bucket requests coalesce into k-wide
            # dispatches; each future carries its minted trace ID
            t0 = time.perf_counter()
            futs = [svc.submit(projs, geom, **opts) for _ in range(6)]
            vols = [f.result() for f in futs]
            wall = time.perf_counter() - t0
            print(f"burst: {len(futs)} requests in {wall:.2f} s")
            for i, f in enumerate(futs):
                print(f"  request {i}: trace_id={f.trace_id}")

            # one streamed session rides along so the trace shows the
            # stream lanes (push instants, fold spans, the tail span)
            session = svc.open_stream(geom, **opts)
            print(f"stream: trace_id={session.trace_id}")
            pa = np.asarray(projs)
            for v in range(geom.n_proj):
                session.push(pa[v], start=v)
            vol = session.close()
            stats = svc.stats()

    # the dispatch spans link each batch back to the requests it served
    print("\nrequest-ID -> batch-dispatch linkage:")
    for e in telemetry.events():
        if e.get("name") == "service.dispatch":
            ids = e["args"].get("trace_ids", [])
            print(f"  dispatch k={e['args'].get('k')} served {ids}")

    n_compiles = sum(1 for e in telemetry.events()
                     if e.get("name") == "compile")
    print(f"\ntrace: {len(telemetry.events())} events "
          f"({n_compiles} compile spans) -> {TRACE_PATH}")
    print("open it at https://ui.perfetto.dev\n")

    print("Prometheus exposition (ServiceStats.export_prometheus):")
    print(stats.export_prometheus())

    assert vols and vol is not None    # keep the results live


if __name__ == "__main__":
    main()
