"""Online reconstruction demo: a simulated scanner streams views while
back-projection runs behind it.

Offline entry points need the whole projection set before the first
kernel launches; a scanner produces views one rotation angle at a time.
This demo drives the streaming path (``runtime/service.py
open_stream``): a producer thread plays scanner — one Shepp-Logan
projection every ``frame_dt`` seconds — while each completed view-chunk
is filtered and folded into the volume as it lands. When the last view
arrives, almost all back-projection work is already done: the measured
"tail" (last view -> finished volume) is a small fraction of what the
same reconstruction costs offline, and the volume is BIT-identical to
the offline result.

    PYTHONPATH=src python examples/stream_recon.py
"""

import threading
import time

import numpy as np

import jax.numpy as jnp

from repro.core import shepp_logan_3d, standard_geometry
from repro.core.forward import forward_project
from repro.runtime.executor import PlanExecutor, ProgramCache
from repro.runtime.planner import plan_reconstruction
from repro.runtime.service import ReconService


def main() -> None:
    geom = standard_geometry(n=32, n_det=48, n_proj=24)
    phantom = shepp_logan_3d(geom.nx, geom.ny, geom.nz)
    projs = np.asarray(forward_project(jnp.asarray(phantom), geom))
    opts = dict(nb=4, proj_batch=4, out="host")

    # offline baseline (also warms the shared program cache and is the
    # parity oracle)
    cache = ProgramCache()
    plan = plan_reconstruction(geom, "algorithm1_mp", ingest="stream",
                               **opts)
    ex = PlanExecutor(geom, plan, cache=cache, pipeline="async")
    _ = np.asarray(ex.reconstruct(jnp.asarray(projs)))   # warm programs
    t0 = time.perf_counter()
    ref = np.asarray(ex.reconstruct(jnp.asarray(projs)))
    offline = time.perf_counter() - t0
    print(f"offline reconstruct: {offline * 1e3:.1f} ms "
          f"({len(plan.chunks)} chunks of {plan.chunk_size} views)")

    # a scanner acquiring slightly slower than we reconstruct — the
    # regime where the whole reconstruction can hide behind the scan
    frame_dt = 1.5 * offline / geom.n_proj
    svc = ReconService(max_inflight=1, cache=cache)
    session = svc.open_stream(geom, **opts)

    def scanner():
        for v in range(geom.n_proj):
            time.sleep(frame_dt)            # ... the gantry rotates ...
            session.push(projs[v], start=v)

    producer = threading.Thread(target=scanner)
    t_scan = time.perf_counter()
    producer.start()
    producer.join()                          # last view just arrived
    t_last = time.perf_counter()
    vol = session.close()                    # tail folds + final flush
    tail = time.perf_counter() - t_last
    rep = session.report

    print(f"scan took {t_last - t_scan:.2f} s "
          f"({frame_dt * 1e3:.1f} ms/view); last view -> volume: "
          f"{tail * 1e3:.1f} ms ({tail / offline:.2f}x the offline wall)")
    print(f"hidden fraction: {rep.hidden_fraction:.2f} of "
          f"{rep.compute_s * 1e3:.1f} ms back-projection overlapped "
          f"the scan")
    print("bit-identical to offline:",
          bool(np.array_equal(np.asarray(vol), ref)))
    svc.close()


if __name__ == "__main__":
    main()
