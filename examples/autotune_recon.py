"""Autotuned reconstruction demo: measure once, serve forever.

The paper's performance-portability claim means the best (variant, loop
order, blocking, pipeline) choice differs per machine. This demo shows
the repo's measured answer (``runtime/autotune.py``):

  1. a ``ReconService`` warms up with ``tune=True`` — the autotuner
     times candidate configurations on THIS machine (bounded budget)
     and persists the winner in the tuning cache;
  2. requests with ``variant="auto"`` resolve the tuned config with a
     microsecond cache lookup — including from a brand-new process;
  3. re-running this script demonstrates the steady state: the warmup
     is a cache hit with ZERO re-measurement.

    PYTHONPATH=src python examples/autotune_recon.py
"""

import os
import time

import numpy as np

import jax.numpy as jnp

from repro.core import fdk_reconstruct, shepp_logan_3d, standard_geometry
from repro.core.forward import forward_project
from repro.runtime.autotune import TuningCache
from repro.runtime.service import ReconService


def main() -> None:
    cache_path = os.environ.get("REPRO_TUNING_CACHE",
                                "/tmp/repro_demo_tuning.json")
    tuning = TuningCache(cache_path)
    geom = standard_geometry(n=32, n_det=48, n_proj=24)
    phantom = shepp_logan_3d(geom.nx, geom.ny, geom.nz)
    projs = forward_project(jnp.asarray(phantom), geom)
    opts = dict(nb=4, tiling=(16, 16, 32), proj_batch=8)

    print(f"tuning cache: {cache_path} "
          f"({len(tuning)} entries before warmup)")

    # 1. tune-at-warmup: measured search on a miss, pure lookup on a hit
    svc = ReconService(max_inflight=2, tuning=tuning)
    t0 = time.perf_counter()
    stats = svc.warmup([geom], tune=True, tune_budget_s=15.0,
                       variant="auto", **opts)
    bucket = stats.buckets[0]
    print(f"warmup(tune=True) took {time.perf_counter() - t0:.1f}s -> "
          f"bucket source={bucket.source} variant={bucket.variant} "
          f"schedule={bucket.schedule} pipeline={bucket.pipeline}")

    # 2. tuned traffic: requests join the tuned bucket
    for _ in range(4):
        vol = svc.reconstruct(projs, geom, variant="auto", tuning=tuning,
                              **opts)
    stats = svc.stats()
    print(f"served {stats.requests} requests "
          f"(p50={stats.p50_ms}ms p99={stats.p99_ms}ms); "
          f"volume range [{float(np.min(vol)):.3f}, "
          f"{float(np.max(vol)):.3f}]")
    svc.close()

    # 3. the façade resolves the same winner from the persisted file —
    #    this is what a fresh process does
    t0 = time.perf_counter()
    fdk_reconstruct(projs, geom, variant="auto", tuning=cache_path, **opts)
    print(f"facade variant='auto' warm request: "
          f"{(time.perf_counter() - t0) * 1e3:.1f} ms "
          f"(config resolved by cache lookup, no measurement)")
    print(f"re-run this script to see warmup(tune=True) hit the cache "
          f"with zero re-measurement ({len(tuning)} entries persisted)")


if __name__ == "__main__":
    main()
