"""Out-of-core reconstruction with the tiled streaming engine.

Reconstructs the same phantom as quickstart.py, but through
`runtime.engine.TiledReconstructor`: the volume is decomposed into
(i, j)-tiles x Z-slabs and each sub-box is back-projected with
translated projection matrices, so the device working set is O(tile)
instead of O(volume) — volumes larger than device memory stream through
unchanged kernels (paper §3.1 locality, iFDK-style slab scale-out).

    PYTHONPATH=src python examples/tiled_recon.py
"""

import numpy as np

import jax.numpy as jnp

from repro.core import fdk_reconstruct, shepp_logan_3d, standard_geometry
from repro.core.forward import forward_project
from repro.runtime.engine import TiledReconstructor


def main():
    geom = standard_geometry(n=32, n_det=48, n_proj=60)
    phantom = jnp.asarray(shepp_logan_3d(geom.nx))
    projections = forward_project(phantom, geom, oversample=2.0)

    # untiled reference (one full-volume variant call)
    ref = fdk_reconstruct(projections, geom, variant="algorithm1_mp",
                          nb=12)
    scale = float(jnp.abs(ref).max())

    # 1. explicit tile shape — 11x13x9 does NOT divide 32^3: edge tiles
    #    shrink and Z-slabs run as mirror pairs + a centered middle slab.
    eng = TiledReconstructor(geom, "algorithm1_mp",
                             tile_shape=(11, 13, 9), nb=12)
    ij, z_units = eng.plan()
    print(f"tile plan: {len(ij)} (i,j)-tiles x {len(z_units)} Z-units, "
          f"working set {eng.working_set_bytes / 2**20:.1f} MiB/tile")
    tiled = eng.reconstruct(projections)
    rmse = float(jnp.sqrt(jnp.mean((tiled - ref) ** 2))) / scale
    print(f"tiled-vs-untiled relative RMSE: {rmse:.2e} "
          f"({'OK' if rmse < 1e-5 else 'FAIL'})")

    # 2. auto-picked tiles from a byte budget (quarter of the untiled
    #    working set) — how a larger-than-memory volume would be run.
    budget = eng.working_set_bytes  # any cap works; reuse the tile's
    auto = TiledReconstructor(geom, "algorithm1_mp", memory_budget=budget,
                              nb=12)
    print(f"auto-picked tile for {budget / 2**20:.1f} MiB budget: "
          f"{auto.tile_shape}")
    tiled2 = auto.reconstruct(projections)
    rmse2 = float(jnp.sqrt(jnp.mean((tiled2 - ref) ** 2))) / scale
    print(f"budget-tiled relative RMSE: {rmse2:.2e} "
          f"({'OK' if rmse2 < 1e-5 else 'FAIL'})")

    # 3. the same path through the pipeline entry point, now with
    #    STREAMED filtering: proj_batch chunks the projections and the
    #    FDK pre-weight + ramp filter runs inside the chunk loop, so the
    #    filtered projection set is never materialized whole.
    tiled3 = fdk_reconstruct(projections, geom, variant="algorithm1_mp",
                             nb=12, tiling=(16, 16, 32), proj_batch=24)
    rmse3 = float(jnp.sqrt(jnp.mean((tiled3 - ref) ** 2))) / scale
    print(f"fdk_reconstruct(tiling=..., proj_batch=24) relative RMSE: "
          f"{rmse3:.2e} ({'OK' if rmse3 < 1e-5 else 'FAIL'})")

    # plan/compile/execute introspection: the ReconPlan is pure data and
    # the jit-program cache compiles once per distinct (variant, shape)
    plan = eng.recon_plan
    print(f"plan: {len(plan.steps)} steps, {len(plan.chunks)} chunk(s), "
          f"{len(plan.program_keys)} distinct programs; "
          f"cache stats {eng.cache_stats()}")

    # interior quality vs ground truth (cone-beam artifacts excluded)
    n = geom.nx
    sl = slice(n // 4, 3 * n // 4)
    ph = np.asarray(phantom)[sl, sl, sl]
    rc = np.asarray(tiled)[sl, sl, sl]
    corr = np.corrcoef(ph.ravel(), rc.ravel())[0, 1]
    print(f"interior corr vs phantom: {corr:.3f}")


if __name__ == "__main__":
    main()
