"""Serve a small model with batched requests: continuous slot-based
batching over a shared decode step (launch/serve.py BatchedServer).

    PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

from repro.configs import ModelConfig
from repro.data import ByteTokenizer
from repro.launch.serve import BatchedServer, Request
from repro.models import build_model


def main():
    cfg = ModelConfig(
        name="serve-tiny", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=384, vocab_size=4096,
        qkv_bias=True, norm="rmsnorm", activation="swiglu",
        dtype="float32", attn_chunk=128, remat=False,
    )
    model = build_model(cfg)
    params = model.init(0)
    tok = ByteTokenizer(cfg.vocab_size)

    server = BatchedServer(cfg, params, slots=4, max_len=96)
    prompts = [
        "The projection matrix maps",
        "Back-projection is",
        "Cone beam computed tomography",
        "Performance portability means",
        "Vectorization on CPUs",
        "The subline buffer caches",
    ]
    pending = [Request(prompt=tok.encode(p), max_new_tokens=24)
               for p in prompts]
    done = []

    # continuous batching: admit when slots free, decode all active
    step = 0
    while pending or any(r is not None for r in server.requests):
        while pending and server.submit(pending[0]):
            done.append(pending.pop(0))
        server.step()
        step += 1
        if step > 500:
            break

    for p, r in zip(prompts, done):
        print(f"prompt={p!r:40s} generated {len(r.out)} tokens "
              f"ids[:8]={r.out[:8]}")
    print(f"served {len(done)} requests in {step} decode steps "
          f"with {server.slots} slots (continuous batching)")


if __name__ == "__main__":
    main()
