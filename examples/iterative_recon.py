"""Iterative reconstruction on the optimized back-projector — the
paper's motivating use case where BP is called repeatedly and dominates
runtime.

Uses the unified API: ``repro.reconstruct(projections, geom, method,
options=ReconOptions(...))`` drives every solver (and FDK) through the
same plan/compile/execute core, and ``repro.solve`` additionally
returns the :class:`~repro.runtime.solvers.SolveReport` with the
residual trajectory and the compile split (everything compiles in
iteration 1; warm iterations dispatch cached programs).

    PYTHONPATH=src python examples/iterative_recon.py
"""

import numpy as np

import jax.numpy as jnp

import repro
from repro import ReconOptions
from repro.core import ball_phantom, standard_geometry
from repro.core.forward import forward_project


def main():
    n = 20
    geom = standard_geometry(n=n, n_det=32, n_proj=24)
    phantom = jnp.asarray(ball_phantom(n, radius=0.55))
    projs = forward_project(phantom, geom, oversample=2.0)

    # one solve call replaces the hand-rolled python loop; the report
    # carries the per-iteration residuals and proves the warm
    # iterations compiled nothing
    vol, rep = repro.solve(projs, geom, "sart", n_iters=6, relax=0.6,
                           nb=8, oversample=1.0)
    for it, resid in enumerate(rep.residuals):
        print(f"iter {it + 1}: projection residual {resid:8.3f}")
    err = float(jnp.sqrt(jnp.mean((vol - phantom) ** 2)))
    interior = np.asarray(vol)[n // 2, n // 2, n // 2]
    print(f"volume rmse {err:.4f}   center voxel {interior:.2f} "
          f"(truth 1.0)")
    print(f"compiles: iter1={rep.compiles_iter1} "
          f"warm={rep.compiles_warm} (warm MUST be 0)   "
          f"wall {rep.wall_s:.2f}s")

    # the same entry point drives every method; ordered subsets
    # (os_sart) converge faster per pass, and the TV prior (fista_tv)
    # wins when views are few or noisy
    opts = ReconOptions(nb=8, relax=0.6, oversample=1.0, n_iters=6)
    for method in ("os_sart", "cgls", "fista_tv"):
        v = repro.reconstruct(projs, geom, method, options=opts,
                              proj_batch=8)
        e = float(jnp.sqrt(jnp.mean((v - phantom) ** 2)))
        print(f"{method:>8}: volume rmse {e:.4f}")

    # iterative recon shares the plan/compile/execute core: the same
    # solve can run tiled + projection-streamed (out-of-core volumes),
    # and precision="bf16" re-keys every program on the reduced-
    # precision axis
    vol_t = repro.reconstruct(
        projs, geom, "sart",
        options=ReconOptions(nb=8, relax=0.6, oversample=1.0, n_iters=1,
                             tiling=(12, 12, n), proj_batch=8))
    first = repro.reconstruct(
        projs, geom, "sart",
        options=ReconOptions(nb=8, relax=0.6, oversample=1.0, n_iters=1))
    drift = float(jnp.abs(vol_t - first).max() / jnp.abs(first).max())
    print(f"tiled+streamed SART vs untiled: rel err {drift:.2e} "
          f"({'OK' if drift < 1e-5 else 'FAIL'})")
    vol_bf16, rep16 = repro.solve(projs, geom, "sart", n_iters=6,
                                  relax=0.6, nb=8, oversample=1.0,
                                  precision="bf16")
    d16 = float(jnp.abs(vol_bf16 - vol).max() / jnp.abs(vol).max())
    print(f"bf16 solve vs f32: rel err {d16:.2e} "
          f"(precision={rep16.precision})")


if __name__ == "__main__":
    main()
