"""Iterative reconstruction (SART) built on the optimized back-projector —
the paper's motivating use case where BP is called repeatedly and
dominates runtime.

    PYTHONPATH=src python examples/iterative_recon.py
"""

import numpy as np

import jax.numpy as jnp

from repro.core import ball_phantom, standard_geometry
from repro.core.fdk import sart_step
from repro.core.forward import forward_project


def main():
    n = 20
    geom = standard_geometry(n=n, n_det=32, n_proj=24)
    phantom = jnp.asarray(ball_phantom(n, radius=0.55))
    projs = forward_project(phantom, geom, oversample=2.0)

    vol = jnp.zeros(geom.volume_shape_zyx, jnp.float32)
    for it in range(6):
        vol = sart_step(vol, projs, geom, relax=0.6, nb=8,
                        variant="algorithm1_mp", oversample=1.0)
        est = forward_project(vol, geom, oversample=1.0)
        resid = float(jnp.sqrt(jnp.mean((est - projs) ** 2)))
        err = float(jnp.sqrt(jnp.mean((vol - phantom) ** 2)))
        print(f"iter {it + 1}: projection residual {resid:8.3f}   "
              f"volume rmse {err:.4f}")
    interior = np.asarray(vol)[n // 2, n // 2, n // 2]
    print(f"center voxel: {interior:.2f} (truth 1.0)")

    # iterative recon shares the plan/compile/execute core: the same
    # step can run tiled + projection-streamed (out-of-core volumes) and
    # with the Pallas kernels (interpret= is threaded through the plan)
    vol_t = sart_step(jnp.zeros(geom.volume_shape_zyx, jnp.float32),
                      projs, geom, relax=0.6, nb=8, oversample=1.0,
                      variant="algorithm1_mp", tiling=(12, 12, n),
                      proj_batch=8)
    first = sart_step(jnp.zeros(geom.volume_shape_zyx, jnp.float32),
                      projs, geom, relax=0.6, nb=8, oversample=1.0)
    drift = float(jnp.abs(vol_t - first).max() / jnp.abs(first).max())
    print(f"tiled+streamed SART step vs untiled: rel err {drift:.2e} "
          f"({'OK' if drift < 1e-5 else 'FAIL'})")


if __name__ == "__main__":
    main()
