"""Quickstart: reconstruct a Shepp-Logan phantom with the paper's
optimized back-projection, and verify against the RTK-style baseline.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import jax.numpy as jnp

from repro.core import (
    fdk_reconstruct, shepp_logan_3d, standard_geometry,
)
from repro.core.forward import forward_project


def main():
    # 1. a CPU-friendly cone-beam geometry (RabbitCT-flavoured)
    geom = standard_geometry(n=32, n_det=48, n_proj=60)
    print(f"geometry: {geom.nw}x{geom.nh}x{geom.n_proj} -> "
          f"{geom.nx}^3, magnification {geom.magnification:.2f}")

    # 2. synthesize projections from a phantom (paper §4.2 protocol)
    phantom = jnp.asarray(shepp_logan_3d(geom.nx))
    projections = forward_project(phantom, geom, oversample=2.0)
    print(f"projections: {projections.shape}, "
          f"max {float(projections.max()):.1f}")

    # 3. reconstruct with the paper's Algorithm 1 (subline+symmetry+batch)
    recon = fdk_reconstruct(projections, geom, variant="algorithm1_mp",
                            nb=12)

    # 4. validate against the RTK-style baseline (paper bar: RMSE < 1e-5)
    baseline = fdk_reconstruct(projections, geom, variant="baseline")
    scale = float(jnp.abs(baseline).max())
    rmse = float(jnp.sqrt(jnp.mean((recon - baseline) ** 2))) / scale
    print(f"variant-vs-baseline relative RMSE: {rmse:.2e} "
          f"({'OK' if rmse < 1e-5 else 'FAIL'})")

    # 5. and against ground truth (interior, cone-beam artifacts excluded)
    n = geom.nx
    sl = slice(n // 4, 3 * n // 4)
    ph = np.asarray(phantom)[sl, sl, sl]
    rc = np.asarray(recon)[sl, sl, sl]
    corr = np.corrcoef(ph.ravel(), rc.ravel())[0, 1]
    print(f"interior corr vs phantom: {corr:.3f}; "
          f"mean {rc.mean():.3f} vs {ph.mean():.3f}")

    # 6. same reconstruction through the Pallas TPU kernel (interpreted)
    recon_pl = fdk_reconstruct(projections, geom, variant="subline_pl")
    rmse_pl = float(jnp.sqrt(jnp.mean((recon_pl - baseline) ** 2))) / scale
    print(f"pallas-kernel relative RMSE: {rmse_pl:.2e} "
          f"({'OK' if rmse_pl < 1e-5 else 'FAIL'})")


if __name__ == "__main__":
    main()
