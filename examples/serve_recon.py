"""Reconstruction as a service: mixed-shape requests through ReconService.

Drives the serving layer (`runtime/service.py`) the way a deployment
would: warm up the shape buckets a scanner fleet will send, then submit
a burst of mixed-shape requests and watch every warm request reuse its
bucket's cached plan + compiled programs (zero retracing) while the
async step pipeline overlaps each tile step's device->host flush with
the next step's scan dispatch. With ``max_batch``/``max_wait_ms`` set,
the BatchFormer additionally coalesces queued same-bucket requests
into ONE batched dispatch stream (mixed buckets never cross-batch) —
the per-bucket occupancy / amortized-cost stats at the end show the
batching in action.

    PYTHONPATH=src python examples/serve_recon.py
    # or with the process-level preset (tcmalloc, quiet logs):
    make serve
"""

import time

import numpy as np

import jax.numpy as jnp

from repro.core import fdk_reconstruct, shepp_logan_3d, standard_geometry
from repro.core.forward import forward_project
from repro.runtime.service import ReconService


def main():
    # two scanner shape classes: a full-res protocol and a preview one
    geom_a = standard_geometry(n=32, n_det=48, n_proj=40)
    geom_b = standard_geometry(n=16, n_det=24, n_proj=40)
    opts = dict(variant="algorithm1_mp", nb=8, tiling=(16, 16, 32),
                proj_batch=16)

    projections = {}
    for name, geom in (("A", geom_a), ("B", geom_b)):
        phantom = jnp.asarray(shepp_logan_3d(geom.nx))
        projections[name] = forward_project(phantom, geom, oversample=2.0)

    # max_batch: up to 4 same-bucket requests share one dispatch
    # stream; max_wait_ms: a partial batch may hold the queue head up
    # to 5 ms for late same-bucket peers (deadline/priority aware)
    with ReconService(max_inflight=2, max_batch=4, max_wait_ms=5.0) as svc:
        # 1. warmup: pay every compile before the first request lands
        t0 = time.perf_counter()
        svc.warmup([geom_a, geom_b], **opts)
        stats = svc.stats()
        print(f"warmup: {len(stats.buckets)} buckets, "
              f"{stats.cache['programs']} cached programs "
              f"in {time.perf_counter() - t0:.2f} s")

        # 2. a FIFO burst of 8 mixed-shape requests (A B A B ...)
        t0 = time.perf_counter()
        futs = [svc.submit(projections["A" if i % 2 == 0 else "B"],
                           geom_a if i % 2 == 0 else geom_b, **opts)
                for i in range(8)]
        vols = [f.result() for f in futs]
        wall = time.perf_counter() - t0
        print(f"burst: 8 requests in {wall:.2f} s "
              f"({wall / 8 * 1e3:.0f} ms/request warm)")

        # 3. warm requests are exact vs the one-shot façade, and the
        #    façade itself can route through the service (service=)
        ref = fdk_reconstruct(projections["A"], geom_a, **opts)
        via = fdk_reconstruct(projections["A"], geom_a, service=svc, **opts)
        err = float(np.max(np.abs(np.asarray(vols[0]) - np.asarray(ref))))
        print(f"service-vs-façade max|diff|: {err:.2e} "
              f"({'OK' if err < 1e-5 else 'FAIL'}); "
              f"fdk_reconstruct(service=...) matches: "
              f"{np.allclose(np.asarray(via), np.asarray(ref), atol=1e-5)}")

        # 4. the snapshot a dashboard would scrape — including batch
        #    occupancy (requests per dispatch; mixed buckets batch
        #    independently) and the amortized per-request cost
        stats = svc.stats()
        print(f"stats: requests={stats.requests} "
              f"bucket hit-rate={stats.hit_rate:.2f} "
              f"dispatches={stats.dispatches} "
              f"occupancy={stats.mean_occupancy} "
              f"cache={stats.cache}")
        for b in stats.buckets:
            print(f"  bucket {b.variant} vol={b.vol_shape_xyz} "
                  f"np={b.n_proj}: requests={b.requests} hits={b.hits} "
                  f"programs_built={b.programs_built} "
                  f"max_batch={b.max_batch} "
                  f"dispatches={b.dispatches} "
                  f"occupancy={b.mean_occupancy} "
                  f"amortized_us/req={b.amortized_us_per_request}")


if __name__ == "__main__":
    main()
